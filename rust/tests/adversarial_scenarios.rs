//! Scenario conformance harness: every adversarial workload
//! ([`Scenario`]) runs through the full pipeline across kernels
//! (native/scalar), shard counts (N=1 vs N=4) and evict modes, and must
//! pass the invariant trio enforced by
//! [`metl::workload::scenario::ScenarioRunner::run_and_verify`]:
//!
//! 1. final sink state ≡ a cold restart with the final schema replaying
//!    the recorded CDC topic verbatim;
//! 2. zero silent drops — counter conservation proves every record was
//!    transformed, dead-lettered or deduped;
//! 3. sinks absorb at-least-once delivery — every run crashes each
//!    egress lane between flush and commit and redelivers everything.

use metl::cache::EvictMode;
use metl::config::PipelineConfig;
use metl::mapper::kernel::KernelMode;
use metl::util::rng::Rng;
use metl::workload::adversarial::{hostile_trace, HostileOp, Scenario};
use metl::workload::scenario::{
    dw_dump, jsonl_by_key, ml_features, ScenarioOutcome, ScenarioRunner,
};
use metl::workload::DmlKind;

/// kernel × shards × evict combinations every scenario must pass.
const COMBOS: [(KernelMode, usize, EvictMode); 4] = [
    (KernelMode::Native, 1, EvictMode::Targeted),
    (KernelMode::Native, 4, EvictMode::Full),
    (KernelMode::Scalar, 1, EvictMode::Full),
    (KernelMode::Scalar, 4, EvictMode::Targeted),
];

fn base_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::small();
    cfg.trace_events = 240;
    cfg.sinks = vec!["dw".into(), "ml".into(), "jsonl".into()];
    cfg
}

/// Run `scenario` across the full combo matrix, returning each outcome.
fn conformance_matrix(scenario: Scenario) -> Vec<ScenarioOutcome> {
    COMBOS
        .iter()
        .map(|&(kernel, shards, evict)| {
            let mut cfg = base_cfg();
            cfg.kernel = kernel;
            cfg.evict = evict;
            ScenarioRunner::new(cfg, scenario)
                .shards(shards)
                .run_and_verify()
                .unwrap_or_else(|e| {
                    panic!("{scenario}/{kernel:?}/N={shards}/{evict:?}: {e}")
                })
        })
        .collect()
}

#[test]
fn uniform_conformance() {
    for outcome in conformance_matrix(Scenario::Uniform) {
        assert_eq!(outcome.events_in, 240);
        assert!(outcome.crash_deliveries > 0, "redelivery exercised");
    }
}

#[test]
fn zipf_conformance() {
    for outcome in conformance_matrix(Scenario::Zipf) {
        assert_eq!(outcome.events_in, outcome.published);
    }
}

#[test]
fn burst_conformance() {
    for outcome in conformance_matrix(Scenario::Burst) {
        assert_eq!(outcome.events_in, outcome.published);
    }
}

#[test]
fn shuffle_conformance() {
    for outcome in conformance_matrix(Scenario::Shuffle) {
        assert_eq!(outcome.events_in, outcome.published);
    }
}

#[test]
fn duplicate_conformance() {
    for outcome in conformance_matrix(Scenario::Duplicate) {
        assert!(
            outcome.duplicates_published > 0,
            "duplicate scenario must inject producer retries"
        );
        assert_eq!(
            outcome.published,
            240 + outcome.duplicates_published as u64
        );
    }
}

#[test]
fn load_storm_conformance() {
    for outcome in conformance_matrix(Scenario::LoadStorm) {
        assert!(
            outcome.snapshot_rows > 0,
            "storm must race snapshot rows onto the live topic"
        );
        assert_eq!(
            outcome.published,
            240 + outcome.snapshot_rows as u64
        );
    }
}

#[test]
fn hot_schema_change_conformance() {
    for outcome in conformance_matrix(Scenario::HotSchemaChange) {
        assert!(
            !outcome.schema_change_log.is_empty(),
            "scenario must evolve the hot schema mid-burst"
        );
    }
}

/// Satellite regression for the sink dedupe gap: crash every egress lane
/// between flush and commit, redeliver everything, and the ML feature
/// moments must be byte-identical to a run that never crashed — a Welford
/// accumulator that sees any observation twice can never recover.
#[test]
fn egress_crash_between_flush_and_commit_does_not_double_count() {
    let scenario = Scenario::Burst;
    let mut control_runner = ScenarioRunner::new(base_cfg(), scenario);
    control_runner.exercise_redelivery = false;
    let (control, control_outcome) = control_runner.run().unwrap();
    assert_eq!(control_outcome.crash_deliveries, 0);

    let (crashed, outcome) =
        ScenarioRunner::new(base_cfg(), scenario).run().unwrap();
    assert!(outcome.crash_deliveries > 0, "crash seam was exercised");

    // every redelivered record was recognized, none re-applied: the ML
    // lane's final drain re-saw the whole CDM topic as delivery dups
    let ml = crashed.sink("ml").unwrap();
    assert_eq!(ml.stats().duplicates, crashed.out_topic.total_records());
    assert_eq!(ml_features(&control), ml_features(&crashed));
    assert_eq!(dw_dump(&control), dw_dump(&crashed));
    assert_eq!(jsonl_by_key(&control), jsonl_by_key(&crashed));
}

/// A sink reset to the topic beginning (dedupe state cleared) rebuilds
/// the exact same warehouse state from the retained CDM topic.
#[test]
fn dw_rebuild_after_reset_matches_original() {
    let (pipeline, _) =
        ScenarioRunner::new(base_cfg(), Scenario::Zipf).run().unwrap();
    let before = dw_dump(&pipeline);
    assert!(!before.is_empty());
    let dw = pipeline.sink("dw").unwrap();
    dw.reset_to_beginning();
    assert!(dw.drain() > 0);
    assert_eq!(dw_dump(&pipeline), before);
}

/// `(seed, scenario)` replays byte-identically: two runs agree on every
/// sink byte, including the JSONL stream and exact ML floats (same
/// accumulation order).
#[test]
fn same_seed_same_scenario_is_byte_identical() {
    let run = || {
        let (p, o) = ScenarioRunner::new(base_cfg(), Scenario::Duplicate)
            .seed(0xBEE5)
            .run()
            .unwrap();
        (dw_dump(&p), ml_features(&p), jsonl_by_key(&p), o.published)
    };
    assert_eq!(run(), run());
}

/// Shard count must not change the outcome: N=1 and N=4 agree on DW and
/// JSONL state exactly and on ML moments up to accumulation-order
/// rounding.
#[test]
fn shard_count_does_not_change_sink_state() {
    let run = |shards: usize| {
        ScenarioRunner::new(base_cfg(), Scenario::Zipf)
            .shards(shards)
            .run()
            .unwrap()
            .0
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(dw_dump(&one), dw_dump(&four));
    assert_eq!(jsonl_by_key(&one), jsonl_by_key(&four));
    let a = ml_features(&one);
    let b = ml_features(&four);
    assert_eq!(a.len(), b.len());
    let close =
        |x: f64, y: f64| (x - y).abs() <= 1e-6 * (1.0 + x.abs().max(y.abs()));
    for (key, (count, mean, var)) in &a {
        let (bc, bm, bv) = b[key];
        assert_eq!(*count, bc, "{key:?} count");
        assert!(
            close(*mean, bm) && close(*var, bv),
            "{key:?}: ({mean}, {var}) vs ({bm}, {bv})"
        );
    }
}

/// High-shard conformance sweep: every scenario through the full
/// invariant trio at 8 and 16 shards — dispatcher fan-out wider than the
/// CDC partition count, so the segmented broker's shared-batch routing
/// (many shards picking from one `SharedBatch`) is exercised hard. Gated
/// behind `METL_HIGH_SHARDS=1` (CI `concurrency` job, release mode).
#[test]
fn high_shard_conformance_sweep() {
    if std::env::var("METL_HIGH_SHARDS").as_deref() != Ok("1") {
        eprintln!("skipping: set METL_HIGH_SHARDS=1 to run");
        return;
    }
    let scenarios = [
        Scenario::Uniform,
        Scenario::Zipf,
        Scenario::Burst,
        Scenario::Shuffle,
        Scenario::Duplicate,
        Scenario::LoadStorm,
        Scenario::HotSchemaChange,
    ];
    for scenario in scenarios {
        for shards in [8usize, 16] {
            for kernel in [KernelMode::Native, KernelMode::Scalar] {
                let mut cfg = base_cfg();
                cfg.kernel = kernel;
                let outcome = ScenarioRunner::new(cfg, scenario)
                    .shards(shards)
                    .run_and_verify()
                    .unwrap_or_else(|e| {
                        panic!("{scenario}/{kernel:?}/N={shards}: {e}")
                    });
                // duplicate/load-storm scenarios publish extra records on
                // top of the 240-event trace, so bound from below only
                assert!(
                    outcome.events_in >= 240,
                    "{scenario}/N={shards}: {} events in",
                    outcome.events_in
                );
            }
        }
    }
}

fn render(op: &HostileOp) -> String {
    match op {
        HostileOp::Dml { service, kind, rank } => {
            let kind = match kind {
                DmlKind::Insert => "insert",
                DmlKind::Update => "update",
                DmlKind::Delete => "delete",
            };
            let rank = match rank {
                Some(r) => r.to_string(),
                None => "-".to_string(),
            };
            format!("dml service={service} kind={kind} rank={rank}")
        }
        HostileOp::SchemaChange { service } => {
            format!("schema-change service={service}")
        }
        HostileOp::SnapshotStorm { service } => {
            format!("snapshot-storm service={service}")
        }
        HostileOp::Drain => "drain".to_string(),
    }
}

/// Golden fixture: one small hostile trace is pinned line-for-line, so
/// any drift in the RNG, the Zipf sampler or the trace shapes shows up as
/// a diff instead of a silent behaviour change.
#[test]
fn golden_zipf_trace_matches_fixture() {
    let mut cfg = PipelineConfig::small();
    cfg.trace_events = 48;
    let ops =
        hostile_trace(&cfg, Scenario::Zipf, &mut Rng::seed_from(0xD1CE));
    let rendered: String = ops
        .iter()
        .map(|op| render(op) + "\n")
        .collect();
    let expected = include_str!("fixtures/hostile_zipf_seed_d1ce.txt");
    assert_eq!(
        rendered, expected,
        "hostile trace drifted from the golden fixture; regenerate \
         tests/fixtures/hostile_zipf_seed_d1ce.txt only for an \
         intentional generator change"
    );
}
