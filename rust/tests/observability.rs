//! Observability contract tests: the Prometheus-style exposition names,
//! the JSON snapshot shape, the Chrome `trace_event` export, and the
//! flight-recorder semantics (dead-lettered records carry their full
//! causal history) are all stable interfaces — drift here breaks
//! scrapers and debugging workflows, so it must show up as a red test.

use std::sync::Arc;

use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::message::cdc::{CdcEvent, CdcOp, CdcSource};
use metl::message::{InMessage, StateI};
use metl::schema::{AttrId, VersionNo};
use metl::trace::Stage;
use metl::util::json::{self, Json};
use metl::workload::adversarial::Scenario;
use metl::workload::scenario::ScenarioRunner;
use metl::workload::{DmlKind, TraceOp};

fn run_small_trace(events: usize) -> Pipeline {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    let ops: Vec<TraceOp> = (0..events)
        .map(|i| TraceOp::Dml { service: i % 4, kind: DmlKind::Insert })
        .collect();
    p.run_trace(&ops).unwrap();
    p
}

/// A wire event stamped with a version the registry never saw: the only
/// way to force a genuine dead letter through the public API.
fn unknown_version_event(p: &Pipeline) -> Arc<CdcEvent> {
    let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
    Arc::new(CdcEvent {
        op: CdcOp::Create,
        before: None,
        after: Some(InMessage {
            key: 7,
            schema,
            version: VersionNo(99),
            state: p.state.current(),
            ts_us: 1,
            fields: vec![(AttrId(0), Json::Num(1.0))],
        }),
        source: CdcSource {
            connector: "postgresql".into(),
            db: "svc0".into(),
            table: "main".into(),
        },
        ts_us: 1,
    })
}

/// Golden name set: every scraper-visible metric name must appear in the
/// exposition. Renaming one is a breaking change (ARCHITECTURE.md
/// §Observability holds the documented table).
#[test]
fn expose_text_contains_golden_metric_names() {
    let p = run_small_trace(8);
    let text = p.expose_text();
    for name in [
        "metl_events_in_total",
        "metl_messages_out_total",
        "metl_transformations_total",
        "metl_dead_letters_total",
        "metl_sync_retries_total",
        "metl_dmm_updates_total",
        "metl_rejected_changes_total",
        "metl_bulk_events_total",
        "metl_trace_spans_total",
        "metl_trace_spans_dropped_total",
        "metl_trace_traces_total",
        "metl_trace_flight_dumps_total",
        "metl_store_wal_bytes_total",
        "metl_store_wal_fsyncs_total",
        "metl_store_segment_gc_total",
        "metl_store_replayed_updates_total",
        "metl_plan_cache_hits_total",
        "metl_plan_cache_misses_total",
        "metl_broker_segments_allocated_total",
        "metl_broker_produce_batches_total",
        "metl_broker_fetch_batches_total",
        "metl_broker_arena_bytes_total",
        "metl_dmm_epoch",
        "metl_epoch_lag",
        "metl_store_segments_live",
        "metl_store_recovery_ms",
        "metl_cache_bytes",
        "metl_cache_hit_rate",
        "metl_shard_events_total",
        "metl_sink_drained_total",
        "metl_sink_flush_errors_total",
        "metl_sink_lag",
        "metl_stage_latency_ns",
    ] {
        assert!(text.contains(name), "exposition lost metric {name}");
        assert!(
            text.contains(&format!("# TYPE {name} "))
                || text.contains(&format!("{name}{{")),
            "{name} has neither a TYPE line nor a labeled sample"
        );
    }
    // labeled series render Prometheus-style
    assert!(text.contains("metl_sink_lag{sink=\"dw\"}"));
    assert!(text.contains("metl_shard_events_total{shard=\"0\"}"));
    assert!(
        text.contains("metl_stage_latency_ns{stage=\"map\",quantile=\"0.99\"}")
    );
    assert!(text.contains("metl_stage_latency_ns_count{stage=\"ingest\"}"));
    // live values made it through: 8 events in, 8 completed traces
    assert!(text.contains("metl_events_in_total 8\n"));
    assert!(text.contains("metl_trace_traces_total 8\n"));
    assert!(text.contains("metl_trace_spans_dropped_total 0\n"));
    // the broker counters are wired: topic creation allocated head
    // segments, the mapped outputs went through arena-sealed batch
    // produces, and the sink drains fetched shared batches
    assert!(p.metrics.broker.segments_allocated.get() >= 2);
    assert!(p.metrics.broker.produce_batches.get() >= 1);
    assert!(p.metrics.broker.fetch_batches.get() >= 1);
    assert!(p.metrics.broker.arena_bytes.get() > 0);
}

#[test]
fn dashboard_shows_stage_and_trace_rows() {
    let p = run_small_trace(5);
    let dash = p.dashboard();
    assert!(dash.contains("METL dashboard"));
    assert!(dash.contains("stage p99"));
    assert!(dash.contains("trace spans"));
    assert!(dash.contains("trace completed"));
}

/// The JSON snapshot mirrors the exposition: same counters, per-stage
/// summaries, and the trace block.
#[test]
fn metrics_snapshot_has_structured_sections() {
    let p = run_small_trace(6);
    let doc = p.metrics_snapshot();
    let events_in = doc
        .get("counters")
        .and_then(|c| c.get("events_in"))
        .and_then(Json::as_f64)
        .unwrap();
    assert_eq!(events_in as u64, 6);
    let traces = doc
        .get("trace")
        .and_then(|t| t.get("traces"))
        .and_then(Json::as_f64)
        .unwrap();
    assert_eq!(traces as u64, 6);
    for stage in ["ingest", "map", "egress", "store", "update", "e2e"] {
        let count = doc
            .get("stages")
            .and_then(|s| s.get(stage))
            .and_then(|s| s.get("count"))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("snapshot lost stage {stage}"));
        if stage == "ingest" || stage == "map" {
            assert_eq!(count as u64, 6, "{stage} count");
        }
    }
    assert!(doc.get("sinks").and_then(Json::as_arr).is_some());
    // the document round-trips through the parser
    let reparsed = json::parse(&doc.to_string()).unwrap();
    assert_eq!(
        reparsed.get("counters").and_then(|c| c.get("events_in")),
        doc.get("counters").and_then(|c| c.get("events_in"))
    );
}

/// A dead-lettered record ships with its full causal history: the DLQ
/// entry's rendered trace names the exact source position
/// (partition/offset), the DMM epoch it mapped against, and the failed
/// map span — and the tracer records a flight dump for the incident.
#[test]
fn dead_letter_carries_provenance_trace() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    let ev = unknown_version_event(&p);
    p.process_event_from(2, 17, &ev);
    assert_eq!(p.metrics.dead_letters.get(), 1);
    let dlq = p.dlq.snapshot();
    assert_eq!(dlq.len(), 1);
    let trace = dlq[0].trace.as_ref().expect("dead letter lost its trace");
    assert!(trace.contains("src=p2@17"), "missing source position: {trace}");
    assert!(trace.contains("epoch=0"), "missing DMM epoch: {trace}");
    assert!(trace.contains("schema=s"), "missing schema stamp: {trace}");
    assert!(trace.contains("map"), "missing map span: {trace}");
    assert!(trace.contains("FAIL"), "failed span not marked: {trace}");
    // the flight recorder dumped the incident with the error attached
    let dumps = p.tracer.dumps();
    assert_eq!(dumps.len(), 1);
    assert!(dumps[0].reason.contains("dead-letter"));
    assert!(dumps[0].render().contains("no mapping column"));
    assert_eq!(p.metrics.trace.flight_dumps.get(), 1);
}

/// The Chrome `trace_event` export parses as JSON and carries the
/// documented shape: complete ("X") events with µs timestamps and the
/// provenance args (trace id, source position, schema, epoch, lane).
#[test]
fn chrome_trace_export_is_well_formed() {
    let p = run_small_trace(10);
    let doc = json::parse(&p.tracer.chrome_trace_json()).unwrap();
    assert_eq!(
        doc.get("displayTimeUnit").and_then(Json::as_str),
        Some("ns")
    );
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    // 10 events × (ingest + map) + 10 egress batch spans at minimum
    assert!(events.len() >= 20, "only {} spans exported", events.len());
    let mut names = std::collections::HashSet::new();
    for ev in events {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("cat").and_then(Json::as_str), Some("metl"));
        assert!(ev.get("ts").and_then(Json::as_f64).is_some());
        assert!(ev.get("dur").and_then(Json::as_f64).is_some());
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        let args = ev.get("args").expect("span lost its args");
        for key in ["trace_id", "partition", "offset", "schema", "epoch"] {
            assert!(args.get(key).is_some(), "args lost {key}");
        }
        names.insert(ev.get("name").and_then(Json::as_str).unwrap().to_owned());
    }
    assert!(names.contains("ingest"));
    assert!(names.contains("map"));
    assert!(names.contains("egress"));
    // egress spans name their sink backend
    assert!(events.iter().any(|ev| {
        ev.get("name").and_then(Json::as_str) == Some("egress")
            && ev.get("args").and_then(|a| a.get("sink")).is_some()
    }));
}

/// The scenario harness extends counter conservation to the tracer:
/// every consumed event finishes exactly one trace and the bounded span
/// buffers never drop silently.
#[test]
fn scenario_conservation_covers_traces() {
    let mut cfg = PipelineConfig::small();
    cfg.trace_events = 120;
    let outcome = ScenarioRunner::new(cfg, Scenario::Zipf)
        .run_and_verify()
        .unwrap();
    assert_eq!(outcome.traces_completed, outcome.events_in);
    assert_eq!(outcome.spans_dropped, 0);
}

/// An in-band heal (unknown version the registry already knows) records
/// a [`Stage::Heal`] span inside the event's trace.
#[test]
fn in_band_heal_records_heal_span() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
        .unwrap();
    {
        let land = p.landscape.read().unwrap();
        let schema = land.dbs[0].tables[0].schema;
        let v = land.dbs[0].tables[0].live_version;
        let mut dpm = (*p.dmm.snapshot()).clone();
        dpm.remove_column(schema, v);
        p.dmm.publish(Arc::new(dpm));
        p.cache.evict_all(StateI(0));
    }
    let mut consumer =
        metl::broker::Consumer::new(p.cdc_topic.clone(), 0, 1);
    for (partition, rec) in consumer.poll(10) {
        p.process_event_from(partition, rec.offset, &rec.value);
    }
    assert_eq!(p.metrics.dead_letters.get(), 0);
    assert_eq!(p.evolution.in_band_updates(), 1);
    let spans = p.tracer.spans();
    assert!(
        spans.iter().any(|(_, s)| s.stage == Stage::Heal && s.ok),
        "heal span missing from {} recorded spans",
        spans.len()
    );
    // the healed event's trace carries the post-heal epoch
    assert!(spans.iter().any(|(ctx, s)| {
        s.stage == Stage::Map && s.ok && ctx.epoch == p.dmm.epoch()
    }));
}

/// Store commits are spans too: a schema change against an attached
/// store records a [`Stage::StoreCommit`] span and a store-stage latency
/// sample.
#[test]
fn store_commit_records_span_and_latency() {
    let dir = metl::util::tmp::TestDir::new("obs-store");
    let p = Pipeline::new(PipelineConfig::small())
        .unwrap()
        .with_store(dir.path())
        .unwrap();
    p.apply_schema_change(0).unwrap();
    assert!(p.metrics.store_latency.count() >= 1);
    let spans = p.tracer.spans();
    assert!(spans
        .iter()
        .any(|(_, s)| s.stage == Stage::StoreCommit && s.ok));
    let text = p.expose_text();
    assert!(text.contains("metl_stage_latency_ns_count{stage=\"store\"} 1"));
}

/// Recovery is a provenance event: restoring from the store records a
/// [`Stage::Recovery`] span and dumps the flight ring so the causal tail
/// from before the restart is preserved.
#[test]
fn store_recovery_dumps_flight_ring() {
    use metl::matrix::dpm::DpmSet;
    let dir = metl::util::tmp::TestDir::new("obs-recovery");
    let p = Pipeline::new(PipelineConfig::small())
        .unwrap()
        .with_store(dir.path())
        .unwrap();
    // traffic before the "crash" populates the flight ring
    let ops: Vec<TraceOp> = (0..4)
        .map(|_| TraceOp::Dml { service: 0, kind: DmlKind::Insert })
        .collect();
    p.run_trace(&ops).unwrap();
    p.apply_schema_change(0).unwrap();
    p.dmm.publish(Arc::new(DpmSet::new(StateI(999))));
    assert!(p.restore_from_store().unwrap());
    let spans = p.tracer.spans();
    assert!(spans.iter().any(|(_, s)| s.stage == Stage::Recovery && s.ok));
    let dumps = p.tracer.dumps();
    assert!(dumps.iter().any(|d| d.reason == "store-recovery"));
    let dump = dumps.iter().find(|d| d.reason == "store-recovery").unwrap();
    assert!(!dump.traces.is_empty(), "flight ring was empty at recovery");
    assert!(dump.render().contains("src=p"));
}

/// `runtime.trace = false` turns collection off end to end: no spans, no
/// completed traces, no flight dumps — while the metrics surfaces keep
/// working.
#[test]
fn tracing_off_collects_nothing() {
    let mut cfg = PipelineConfig::small();
    cfg.trace = false;
    let p = Pipeline::new(cfg).unwrap();
    let ops: Vec<TraceOp> = (0..8)
        .map(|_| TraceOp::Dml { service: 0, kind: DmlKind::Insert })
        .collect();
    p.run_trace(&ops).unwrap();
    assert!(!p.tracer.enabled());
    assert_eq!(p.tracer.span_count(), 0);
    assert_eq!(p.metrics.trace.traces.get(), 0);
    assert_eq!(p.metrics.trace.spans.get(), 0);
    // a dead letter still lands in the DLQ, just without the trace
    let ev = unknown_version_event(&p);
    p.process_event_from(1, 3, &ev);
    let dlq = p.dlq.snapshot();
    assert_eq!(dlq.len(), 1);
    assert!(dlq[0].trace.is_none());
    assert!(p.tracer.dumps().is_empty());
    // exposition and dashboard still render
    assert!(p.expose_text().contains("metl_events_in_total 9\n"));
    assert!(p.dashboard().contains("METL dashboard"));
}

/// Sharded runs trace too, with per-event provenance intact: every event
/// completes a trace, and worker spans carry shard ids.
#[test]
fn sharded_run_traces_every_event() {
    let mut cfg = PipelineConfig::small();
    cfg.trace_events = 64;
    let p = Pipeline::new(cfg).unwrap();
    let mut rng = metl::util::rng::Rng::seed_from(0x0B5);
    let ops = metl::workload::day_trace(&p.cfg, &mut rng);
    let report = p.run_trace_sharded(&ops, 4).unwrap();
    assert!(report.events > 0);
    assert_eq!(
        p.metrics.trace.traces.get(),
        report.events,
        "every consumed event must finish exactly one trace"
    );
    assert_eq!(p.metrics.trace.spans_dropped.get(), 0);
    assert_eq!(p.metrics.ingest_latency.count() as u64, report.events);
}
