//! Integration tests over the matrix subsystem: the paper's two worked
//! figures end to end, and the compaction/update claims at profile scale.

use metl::config::PipelineConfig;
use metl::matrix::compaction::CompactionStats;
use metl::matrix::decompact::recreate_dpm;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::matrix::fixtures::{
    fig5_drop_old_cdm, fig5_matrix, fig5_trees, fig6_matrix, fig6_trees,
};
use metl::matrix::update::{auto_update, ChangeCase};
use metl::message::StateI;
use metl::schema::ExtractType;
use metl::workload;

/// Figure 5, exactly as printed: the 6x5 live matrix holds 30 elements;
/// Alg 2 compacts to 7, Alg 3 to 5 plus one special null block.
#[test]
fn figure5_worked_example_exact() {
    let (t, mut c) = fig5_trees();
    fig5_drop_old_cdm(&mut c); // §5.1: outdated CDM version deleted
    let m = fig5_matrix(&t, &c);
    let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
    let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
    let stats = CompactionStats::measure(&m, &t, &c, &dpm, &dusb);
    assert_eq!(stats.matrix_elements, 30, "fig 5: 30 live elements");
    assert_eq!(stats.ones, 7);
    assert_eq!(stats.dpm_elements, 7, "fig 5: Alg 2 -> 7 elements");
    assert_eq!(stats.dusb_elements, 5, "fig 5: Alg 3 -> 5 elements");
    assert_eq!(stats.dusb_special_nulls, 1, "fig 5: the special 6th element");
    // both roundtrip to the same matrix
    assert_eq!(dpm.decompact(m.n_rows(), m.n_cols()), m);
    assert_eq!(dusb.decompact(&t, &c), m);
}

/// Figure 6, both update events in sequence, checked against the printed
/// matrix values.
#[test]
fn figure6_worked_example_exact() {
    let (mut t, mut c) = fig6_trees();
    let m = fig6_matrix(&t, &c);
    let mut dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
    assert_eq!(dpm.n_elements(), 6);

    // event (1): added extracting version s1.v3 with a7 ≡ a4 ≡ a1
    let s1 = t.schema_by_name("s1").unwrap();
    let v3 = t.add_version(s1, &[("a1".into(), ExtractType::Int64, true)]);
    let r1 = auto_update(
        &mut dpm,
        &t,
        &c,
        ChangeCase::AddedSchemaVersion { schema: s1, v: v3 },
        StateI(1),
    );
    // fig 6 column s1.v3: only c1 = 1
    assert_eq!(r1.elements_added, 1);
    let col = dpm.column(s1, v3);
    assert_eq!(col.len(), 1);
    let e1 = c.entity_by_name("s1cdm").unwrap();
    assert_eq!(col[0].key.entity, e1);
    // c2's mapping (a6, no descendant in v3) shrank: notice raised
    assert!(!r1.notices.is_empty());

    // event (2): added CDM version (c3≡c1, c4≡c2); old version rows deleted
    let w2 = c.add_version(
        e1,
        &[
            ("c1".into(), metl::cdm::CdmType::Integer, String::new()),
            ("c2".into(), metl::cdm::CdmType::Integer, String::new()),
        ],
    );
    let r2 = auto_update(
        &mut dpm,
        &t,
        &c,
        ChangeCase::AddedCdmVersion { entity: e1, w: w2 },
        StateI(2),
    );
    // fig 6: rows c3/c4 carry the copied values of c1/c2 across all three
    // column blocks; v1 rows deleted (red)
    assert_eq!(r2.blocks_added, 3);
    assert_eq!(r2.elements_added, 5); // (a1,a3) + (a4,a6) + (a7)
    assert_eq!(r2.blocks_removed, 3);
    assert!(dpm.row(e1, metl::cdm::CdmVersionNo(1)).is_empty());
    let new_rows: usize = dpm.row(e1, w2).iter().map(|b| b.rank()).sum();
    assert_eq!(new_rows, 5);
    // the untouched entity survives
    let e2 = c.entity_by_name("s2cdm").unwrap();
    assert_eq!(
        dpm.row(e2, metl::cdm::CdmVersionNo(1))
            .iter()
            .map(|b| b.rank())
            .sum::<usize>(),
        2
    );
}

/// Paper claim (§5.3): >99% compaction for the standard use case, with the
/// aggressive strategy at least as good, at paper_day scale.
#[test]
fn compaction_claims_at_paper_scale() {
    let cfg = PipelineConfig::paper_day();
    let land = workload::generate(&cfg);
    let dpm =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    let dusb =
        DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    let stats = CompactionStats::measure(
        &land.matrix, &land.tree, &land.cdm, &dpm, &dusb,
    );
    assert!(stats.dpm_ratio() > 0.99, "DPM ratio {}", stats.dpm_ratio());
    assert!(stats.dusb_ratio() > 0.99, "DUSB ratio {}", stats.dusb_ratio());
    assert!(stats.dusb_ratio() >= stats.dpm_ratio());
    assert!(stats.null_block_ratio() > 0.9, "most blocks are null");
}

/// The hybrid restore path is exact at scale: DUSB -> M -> DPM equals the
/// directly-built DPM (the §6.2 restart invariant).
#[test]
fn restore_path_exact_at_scale() {
    let cfg = PipelineConfig::paper_day();
    let land = workload::generate(&cfg);
    let direct =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(3))
            .unwrap();
    let dusb =
        DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(3))
            .unwrap();
    // DUSB decompacts to the very matrix it was built from
    assert_eq!(dusb.decompact(&land.tree, &land.cdm), land.matrix);
    let restored = recreate_dpm(&dusb, &land.tree, &land.cdm).unwrap();
    assert!(direct.same_elements(&restored));
}

/// A storm of version additions applied through Alg 5 must leave the DMM
/// identical to a from-scratch recompute of the equivalently-updated
/// ground-truth matrix.
#[test]
fn update_storm_equals_recompute() {
    let cfg = PipelineConfig::small();
    let mut land = workload::generate(&cfg);
    let mut dpm =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    for (i, s_idx) in [0usize, 1, 2, 3, 0, 1].iter().enumerate() {
        let schema = land.tree.schemas().nth(*s_idx).unwrap().id;
        let fields = workload::evolved_fields(&land.tree, schema);
        let v = land.tree.add_version(schema, &fields);
        auto_update(
            &mut dpm,
            &land.tree,
            &land.cdm,
            ChangeCase::AddedSchemaVersion { schema, v },
            StateI(i as u64 + 1),
        );
        // mirror into ground truth exactly like the pipeline does
        let (nr, nc) = (land.cdm.n_attr_ids(), land.tree.n_attr_ids());
        land.matrix.grow(nr, nc);
        for block in dpm.column(schema, v) {
            for &(q, p) in &block.elements {
                land.matrix.set(q.index(), p.index(), true);
            }
        }
    }
    let recomputed =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(6))
            .unwrap();
    assert!(dpm.same_elements(&recomputed));
}

/// Version deletions through Alg 5 equal recompute on the cleared matrix.
#[test]
fn deletion_equals_recompute() {
    let cfg = PipelineConfig::small();
    let mut land = workload::generate(&cfg);
    let mut dpm =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    let schema = land.tree.schemas().next().unwrap().id;
    let v1 = metl::schema::VersionNo(1);
    auto_update(
        &mut dpm,
        &land.tree,
        &land.cdm,
        ChangeCase::DeletedSchemaVersion { schema, v: v1 },
        StateI(1),
    );
    // ground truth: clear the column range and delete the version
    let sv = land.tree.version(schema, v1).unwrap().clone();
    land.matrix.clear_block(
        0..land.matrix.n_rows(),
        sv.col_start()..sv.col_start() + sv.width(),
    );
    land.tree.delete_version(schema, v1);
    let recomputed =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(1))
            .unwrap();
    assert!(dpm.same_elements(&recomputed));
}
