//! Property-based tests (own harness — proptest is unavailable offline):
//! randomized landscapes/matrices across many seeds, asserting the
//! system's core invariants.

use std::sync::Arc;

use metl::cache::DcpmCache;
use metl::config::PipelineConfig;
use metl::mapper::baseline::BaselineMapper;
use metl::mapper::parallel::ParallelMapper;
use metl::matrix::decompact::recreate_dpm;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::matrix::update::{auto_update, ChangeCase};
use metl::message::{InMessage, OutMessage, StateI};
use metl::util::json::Json;
use metl::util::rng::Rng;
use metl::workload;

/// Randomized config within paper-plausible bounds.
fn random_cfg(rng: &mut Rng) -> PipelineConfig {
    let mut cfg = PipelineConfig::small();
    cfg.n_services = 2 + rng.gen_range(6) as usize;
    cfg.attrs_per_schema = 3 + rng.gen_range(8) as usize;
    cfg.versions_per_schema = 1 + rng.gen_range(6) as usize;
    cfg.n_entities = 1 + rng.gen_range(4) as usize;
    cfg.attrs_per_entity = 3 + rng.gen_range(10) as usize;
    cfg.mapped_fraction = 0.2 + rng.f64() * 0.7;
    cfg.seed = rng.next_u64();
    cfg
}

/// Invariant: both compaction strategies decompact back to the exact
/// matrix, for any generated landscape.
#[test]
fn prop_compaction_roundtrips() {
    let mut meta = Rng::seed_from(0xC0FFEE);
    for trial in 0..30 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dpm = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let dusb = DusbSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap();
        assert_eq!(
            dpm.decompact(land.matrix.n_rows(), land.matrix.n_cols()),
            land.matrix,
            "trial {trial}: DPM roundtrip"
        );
        assert_eq!(
            dusb.decompact(&land.tree, &land.cdm),
            land.matrix,
            "trial {trial}: DUSB roundtrip (seed {})",
            cfg.seed
        );
        // aggressive strategy never stores more
        assert!(dusb.n_elements() <= dpm.n_elements());
        // the restore view equals the direct build
        let restored = recreate_dpm(&dusb, &land.tree, &land.cdm).unwrap();
        assert!(dpm.same_elements(&restored), "trial {trial}: restore");
    }
}

/// Invariant: DUSB JSON serialization is lossless.
#[test]
fn prop_dusb_json_roundtrip() {
    let mut meta = Rng::seed_from(0xD05A);
    for _ in 0..20 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dusb = DusbSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(9),
        )
        .unwrap();
        let parsed =
            metl::util::json::parse(&dusb.to_json().to_string()).unwrap();
        let back = DusbSet::from_json(&parsed).unwrap();
        assert_eq!(back.decompact(&land.tree, &land.cdm), land.matrix);
        assert_eq!(back.n_special_nulls(), dusb.n_special_nulls());
    }
}

/// Invariant: Alg 6 outputs equal Alg 1 outputs after densification, for
/// random messages over random landscapes.
#[test]
fn prop_alg6_equals_dense_alg1() {
    let mut meta = Rng::seed_from(0xA161);
    for trial in 0..15 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dpm = Arc::new(
            DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap(),
        );
        let cache = Arc::new(DcpmCache::new(StateI(0)));
        let fast = ParallelMapper::new(dpm, cache);
        let slow = BaselineMapper::new(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        );
        let mut rng = Rng::seed_from(cfg.seed ^ 1);
        for k in 0..20u64 {
            let s_idx = rng.gen_range(cfg.n_services as u64) as usize;
            let node = land.tree.schemas().nth(s_idx).unwrap();
            let v = *rng.choose(&node.versions).unwrap();
            let sv = land.tree.version(node.id, v).unwrap();
            let row = metl::source::random_row(
                &land.tree, node.id, v, k, &mut rng, 0.4,
            );
            let sparse = InMessage {
                key: k,
                schema: node.id,
                version: v,
                state: StateI(0),
                ts_us: 0,
                fields: sv.attrs.iter().copied().zip(row.values).collect(),
            };
            // a version with zero mapped blocks is UnknownColumn on the
            // dense lane; Alg 1 produces all-null outputs there — both
            // mean "nothing reaches the CDM"
            let mut fast_outs = match fast.map(&sparse.to_dense()) {
                Ok(outs) => outs,
                Err(metl::mapper::MapError::UnknownColumn { .. }) => vec![],
                Err(e) => panic!("trial {trial}: {e}"),
            };
            let mut slow_outs: Vec<OutMessage> = slow
                .map(&sparse)
                .unwrap()
                .into_iter()
                .map(|o| OutMessage {
                    fields: o
                        .fields
                        .into_iter()
                        .filter(|(_, val)| !val.is_null())
                        .collect(),
                    ..o
                })
                .filter(|o| !o.fields.is_empty())
                .collect();
            fast_outs.sort_by_key(|o| (o.entity, o.version));
            slow_outs.sort_by_key(|o| (o.entity, o.version));
            assert_eq!(fast_outs, slow_outs, "trial {trial} msg {k}");
        }
    }
}

/// Invariant: Alg 5 incremental updates equal recompute-from-scratch for
/// random version-addition storms.
#[test]
fn prop_update_equals_recompute() {
    let mut meta = Rng::seed_from(0x5EED);
    for trial in 0..12 {
        let cfg = random_cfg(&mut meta);
        let mut land = workload::generate(&cfg);
        let mut dpm = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap();
        let storms = 1 + meta.gen_range(4) as usize;
        for i in 0..storms {
            let s_idx = meta.gen_range(cfg.n_services as u64) as usize;
            let schema = land.tree.schemas().nth(s_idx).unwrap().id;
            let fields = workload::evolved_fields(&land.tree, schema);
            let v = land.tree.add_version(schema, &fields);
            auto_update(
                &mut dpm,
                &land.tree,
                &land.cdm,
                ChangeCase::AddedSchemaVersion { schema, v },
                StateI(i as u64 + 1),
            );
            let (nr, nc) = (land.cdm.n_attr_ids(), land.tree.n_attr_ids());
            land.matrix.grow(nr, nc);
            for block in dpm.column(schema, v) {
                for &(q, p) in &block.elements {
                    land.matrix.set(q.index(), p.index(), true);
                }
            }
        }
        let recomputed = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(99),
        )
        .unwrap();
        assert!(dpm.same_elements(&recomputed), "trial {trial}");
    }
}

/// Invariant: JSON codec roundtrips arbitrary values built from the sim's
/// value constructors.
#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::seed_from(0x1503);
    for _ in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = metl::util::json::parse(&text).unwrap();
        assert_eq!(back, v, "{text}");
        let pretty = v.to_pretty();
        assert_eq!(metl::util::json::parse(&pretty).unwrap(), v);
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.gen_range(2_000_001) as f64 - 1e6) / 8.0),
        3 => {
            let n = rng.gen_range(12) as usize;
            Json::Str(
                (0..n)
                    .map(|_| {
                        let c = rng.gen_range(96) as u8 + 32;
                        c as char
                    })
                    .collect(),
            )
        }
        4 => Json::Arr(
            (0..rng.gen_range(4)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.gen_range(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Invariant: the state-sync check fires for every skewed state, never
/// for matching states (mapper-level §3.4 contract).
#[test]
fn prop_state_sync_contract() {
    let mut meta = Rng::seed_from(77);
    let cfg = random_cfg(&mut meta);
    let land = workload::generate(&cfg);
    for state in 0..5u64 {
        let dpm = Arc::new(
            DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(state),
            )
            .unwrap(),
        );
        let cache = Arc::new(DcpmCache::new(StateI(state)));
        let mapper = ParallelMapper::new(dpm, cache);
        let node = land.tree.schemas().next().unwrap();
        let v = *node.versions.last().unwrap();
        let sv = land.tree.version(node.id, v).unwrap();
        for msg_state in 0..5u64 {
            let msg = InMessage {
                key: 1,
                schema: node.id,
                version: v,
                state: StateI(msg_state),
                ts_us: 0,
                fields: vec![(sv.attrs[0], Json::Num(1.0))],
            };
            let result = mapper.map(&msg);
            if msg_state == state {
                assert!(result.is_ok());
            } else {
                assert!(matches!(
                    result,
                    Err(metl::mapper::MapError::StateMismatch { .. })
                ));
            }
        }
    }
}
