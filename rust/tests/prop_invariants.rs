//! Property-based tests (own harness — proptest is unavailable offline):
//! randomized landscapes/matrices across many seeds, asserting the
//! system's core invariants.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use metl::broker::{Broker, Consumer, Topic};
use metl::cache::DcpmCache;
use metl::config::PipelineConfig;
use metl::coordinator::EpochDmm;
use metl::mapper::baseline::BaselineMapper;
use metl::mapper::parallel::ParallelMapper;
use metl::matrix::decompact::recreate_dpm;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::matrix::update::{auto_update, prepare_update, ChangeCase};
use metl::message::{InMessage, OutMessage, StateI};
use metl::util::json::Json;
use metl::util::rng::{Rng, Zipf};
use metl::workload::adversarial::{
    duplicate_delivery, hostile_trace, shuffle_bounded, HostileOp, Scenario,
};
use metl::workload::{self, DmlKind, Landscape};

/// Randomized config within paper-plausible bounds.
fn random_cfg(rng: &mut Rng) -> PipelineConfig {
    let mut cfg = PipelineConfig::small();
    cfg.n_services = 2 + rng.gen_range(6) as usize;
    cfg.attrs_per_schema = 3 + rng.gen_range(8) as usize;
    cfg.versions_per_schema = 1 + rng.gen_range(6) as usize;
    cfg.n_entities = 1 + rng.gen_range(4) as usize;
    cfg.attrs_per_entity = 3 + rng.gen_range(10) as usize;
    cfg.mapped_fraction = 0.2 + rng.f64() * 0.7;
    cfg.seed = rng.next_u64();
    cfg
}

/// Invariant: both compaction strategies decompact back to the exact
/// matrix, for any generated landscape.
#[test]
fn prop_compaction_roundtrips() {
    let mut meta = Rng::seed_from(0xC0FFEE);
    for trial in 0..30 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dpm = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let dusb = DusbSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap();
        assert_eq!(
            dpm.decompact(land.matrix.n_rows(), land.matrix.n_cols()),
            land.matrix,
            "trial {trial}: DPM roundtrip"
        );
        assert_eq!(
            dusb.decompact(&land.tree, &land.cdm),
            land.matrix,
            "trial {trial}: DUSB roundtrip (seed {})",
            cfg.seed
        );
        // aggressive strategy never stores more
        assert!(dusb.n_elements() <= dpm.n_elements());
        // the restore view equals the direct build
        let restored = recreate_dpm(&dusb, &land.tree, &land.cdm).unwrap();
        assert!(dpm.same_elements(&restored), "trial {trial}: restore");
    }
}

/// Invariant: DUSB JSON serialization is lossless.
#[test]
fn prop_dusb_json_roundtrip() {
    let mut meta = Rng::seed_from(0xD05A);
    for _ in 0..20 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dusb = DusbSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(9),
        )
        .unwrap();
        let parsed =
            metl::util::json::parse(&dusb.to_json().to_string()).unwrap();
        let back = DusbSet::from_json(&parsed).unwrap();
        assert_eq!(back.decompact(&land.tree, &land.cdm), land.matrix);
        assert_eq!(back.n_special_nulls(), dusb.n_special_nulls());
    }
}

/// Invariant: Alg 6 outputs equal Alg 1 outputs after densification, for
/// random messages over random landscapes.
#[test]
fn prop_alg6_equals_dense_alg1() {
    let mut meta = Rng::seed_from(0xA161);
    for trial in 0..15 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dpm = Arc::new(
            DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap(),
        );
        let cache = Arc::new(DcpmCache::new(StateI(0)));
        let fast = ParallelMapper::new(dpm, cache);
        let slow = BaselineMapper::new(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        );
        let mut rng = Rng::seed_from(cfg.seed ^ 1);
        for k in 0..20u64 {
            let s_idx = rng.gen_range(cfg.n_services as u64) as usize;
            let node = land.tree.schemas().nth(s_idx).unwrap();
            let v = *rng.choose(&node.versions).unwrap();
            let sv = land.tree.version(node.id, v).unwrap();
            let row = metl::source::random_row(
                &land.tree, node.id, v, k, &mut rng, 0.4,
            );
            let sparse = InMessage {
                key: k,
                schema: node.id,
                version: v,
                state: StateI(0),
                ts_us: 0,
                fields: sv.attrs.iter().copied().zip(row.values).collect(),
            };
            // a version with zero mapped blocks is UnknownColumn on the
            // dense lane; Alg 1 produces all-null outputs there — both
            // mean "nothing reaches the CDM"
            let mut fast_outs = match fast.map(&sparse.to_dense()) {
                Ok(outs) => outs,
                Err(metl::mapper::MapError::UnknownColumn { .. }) => vec![],
                Err(e) => panic!("trial {trial}: {e}"),
            };
            let mut slow_outs: Vec<OutMessage> = slow
                .map(&sparse)
                .unwrap()
                .into_iter()
                .map(|o| OutMessage {
                    fields: o
                        .fields
                        .into_iter()
                        .filter(|(_, val)| !val.is_null())
                        .collect(),
                    ..o
                })
                .filter(|o| !o.fields.is_empty())
                .collect();
            fast_outs.sort_by_key(|o| (o.entity, o.version));
            slow_outs.sort_by_key(|o| (o.entity, o.version));
            assert_eq!(fast_outs, slow_outs, "trial {trial} msg {k}");
        }
    }
}

/// Invariant: Alg 5 incremental updates equal recompute-from-scratch for
/// random version-addition storms.
#[test]
fn prop_update_equals_recompute() {
    let mut meta = Rng::seed_from(0x5EED);
    for trial in 0..12 {
        let cfg = random_cfg(&mut meta);
        let mut land = workload::generate(&cfg);
        let mut dpm = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap();
        let storms = 1 + meta.gen_range(4) as usize;
        for i in 0..storms {
            let s_idx = meta.gen_range(cfg.n_services as u64) as usize;
            let schema = land.tree.schemas().nth(s_idx).unwrap().id;
            let fields = workload::evolved_fields(&land.tree, schema);
            let v = land.tree.add_version(schema, &fields);
            auto_update(
                &mut dpm,
                &land.tree,
                &land.cdm,
                ChangeCase::AddedSchemaVersion { schema, v },
                StateI(i as u64 + 1),
            );
            let (nr, nc) = (land.cdm.n_attr_ids(), land.tree.n_attr_ids());
            land.matrix.grow(nr, nc);
            for block in dpm.column(schema, v) {
                for &(q, p) in &block.elements {
                    land.matrix.set(q.index(), p.index(), true);
                }
            }
        }
        let recomputed = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(99),
        )
        .unwrap();
        assert!(dpm.same_elements(&recomputed), "trial {trial}");
    }
}

/// Map every (schema, version) live in the tree through both sets and
/// require identical outputs — the observable half of the update/map
/// commutativity invariant. Both sets must carry the same state.
fn assert_mapping_equal(land: &Landscape, a: &DpmSet, b: &DpmSet, seed: u64) {
    assert_eq!(a.state, b.state, "commutativity needs matching states");
    let fast_a = ParallelMapper::new(
        Arc::new(a.clone()),
        Arc::new(DcpmCache::new(a.state)),
    );
    let fast_b = ParallelMapper::new(
        Arc::new(b.clone()),
        Arc::new(DcpmCache::new(b.state)),
    );
    let map_sorted = |mapper: &ParallelMapper, msg: &InMessage| -> Vec<OutMessage> {
        match mapper.map(msg) {
            Ok(mut outs) => {
                outs.sort_by_key(|o| (o.entity, o.version));
                outs
            }
            // a version whose column vanished entirely maps to nothing
            Err(metl::mapper::MapError::UnknownColumn { .. }) => Vec::new(),
            Err(e) => panic!("unexpected map error: {e}"),
        }
    };
    let mut rng = Rng::seed_from(seed);
    for node in land.tree.schemas() {
        for &v in &node.versions {
            let sv = land.tree.version(node.id, v).unwrap();
            for k in 0..3u64 {
                let row = metl::source::random_row(
                    &land.tree, node.id, v, k, &mut rng, 0.3,
                );
                let msg = InMessage {
                    key: k,
                    schema: node.id,
                    version: v,
                    state: a.state,
                    ts_us: 0,
                    fields: sv
                        .attrs
                        .iter()
                        .copied()
                        .zip(row.values)
                        .collect(),
                }
                .to_dense();
                assert_eq!(
                    map_sorted(&fast_a, &msg),
                    map_sorted(&fast_b, &msg),
                    "schema {:?} v{} msg {k}",
                    node.id,
                    v.0
                );
            }
        }
    }
}

/// Satellite invariant: **update/map commutativity** across all four Alg-5
/// triggers. For seeded landscapes, mapping through the incrementally
/// updated `ᵢ₊₁𝔇𝔓𝔐` must equal mapping through a from-scratch rebuild of
/// the equivalently updated ground-truth matrix.
#[test]
fn prop_update_map_commutes_with_recompute() {
    let mut meta = Rng::seed_from(0xC0AA17);
    for trial in 0..8 {
        let cfg = random_cfg(&mut meta);
        let msg_seed = meta.next_u64();

        // --- case 3: added extracting version ---------------------------
        {
            let mut land = workload::generate(&cfg);
            let mut dpm = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(0),
            )
            .unwrap();
            let schema = land.tree.schemas().next().unwrap().id;
            let fields = workload::evolved_fields(&land.tree, schema);
            let v = land.tree.add_version(schema, &fields);
            auto_update(
                &mut dpm,
                &land.tree,
                &land.cdm,
                ChangeCase::AddedSchemaVersion { schema, v },
                StateI(1),
            );
            dpm.verify_one_to_one()
                .unwrap_or_else(|k| panic!("trial {trial}: 1:1 broken at {k:?}"));
            land.matrix
                .grow(land.cdm.n_attr_ids(), land.tree.n_attr_ids());
            for block in dpm.column(schema, v) {
                for &(q, p) in &block.elements {
                    land.matrix.set(q.index(), p.index(), true);
                }
            }
            let rebuilt = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(1),
            )
            .unwrap();
            assert!(dpm.same_elements(&rebuilt), "trial {trial}: case 3");
            assert_mapping_equal(&land, &dpm, &rebuilt, msg_seed ^ 3);
        }

        // --- case 1: deleted extracting version -------------------------
        {
            let mut land = workload::generate(&cfg);
            let mut dpm = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(0),
            )
            .unwrap();
            let schema = land.tree.schemas().next().unwrap().id;
            let v = metl::schema::VersionNo(1);
            auto_update(
                &mut dpm,
                &land.tree,
                &land.cdm,
                ChangeCase::DeletedSchemaVersion { schema, v },
                StateI(1),
            );
            let sv = land.tree.version(schema, v).unwrap().clone();
            land.matrix.clear_block(
                0..land.matrix.n_rows(),
                sv.col_start()..sv.col_start() + sv.width(),
            );
            land.tree.delete_version(schema, v);
            let rebuilt = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(1),
            )
            .unwrap();
            assert!(dpm.same_elements(&rebuilt), "trial {trial}: case 1");
            assert_mapping_equal(&land, &dpm, &rebuilt, msg_seed ^ 1);
        }

        // --- case 2: deleted CDM version --------------------------------
        {
            let mut land = workload::generate(&cfg);
            let mut dpm = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(0),
            )
            .unwrap();
            let entity = land.cdm.entities().next().unwrap().id;
            let w = metl::cdm::CdmVersionNo(1);
            auto_update(
                &mut dpm,
                &land.tree,
                &land.cdm,
                ChangeCase::DeletedCdmVersion { entity, w },
                StateI(1),
            );
            let cv = land.cdm.version(entity, w).unwrap().clone();
            land.matrix.clear_block(
                cv.row_start()..cv.row_start() + cv.height(),
                0..land.matrix.n_cols(),
            );
            land.cdm.delete_version(entity, w);
            let rebuilt = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(1),
            )
            .unwrap();
            assert!(dpm.same_elements(&rebuilt), "trial {trial}: case 2");
            assert_mapping_equal(&land, &dpm, &rebuilt, msg_seed ^ 2);
        }

        // --- case 4: added CDM version (plus §5.4.3 cleanup) ------------
        {
            let mut land = workload::generate(&cfg);
            let mut dpm = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(0),
            )
            .unwrap();
            let entity = land.cdm.entities().next().unwrap().id;
            let w1 = metl::cdm::CdmVersionNo(1);
            let cv1 = land.cdm.version(entity, w1).unwrap().clone();
            let fields: Vec<(String, metl::cdm::CdmType, String)> = cv1
                .attrs
                .iter()
                .map(|&q| {
                    let a = land.cdm.attr(q);
                    (a.name.clone(), a.ty, a.description.clone())
                })
                .collect();
            let w2 = land.cdm.add_version(entity, &fields);
            auto_update(
                &mut dpm,
                &land.tree,
                &land.cdm,
                ChangeCase::AddedCdmVersion { entity, w: w2 },
                StateI(1),
            );
            dpm.verify_one_to_one()
                .unwrap_or_else(|k| panic!("trial {trial}: 1:1 broken at {k:?}"));
            land.matrix
                .grow(land.cdm.n_attr_ids(), land.tree.n_attr_ids());
            for block in dpm.row(entity, w2) {
                for &(q, p) in &block.elements {
                    land.matrix.set(q.index(), p.index(), true);
                }
            }
            // §5.4.3: the previous CDM version's rows are deleted
            land.matrix.clear_block(
                cv1.row_start()..cv1.row_start() + cv1.height(),
                0..land.matrix.n_cols(),
            );
            let rebuilt = DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(1),
            )
            .unwrap();
            assert!(dpm.same_elements(&rebuilt), "trial {trial}: case 4");
            assert_mapping_equal(&land, &dpm, &rebuilt, msg_seed ^ 4);
        }
    }
}

/// Satellite invariant: an epoch swap mid-stream never yields a message
/// mapped by a mixed old/new snapshot — every mapped result equals the
/// pure-old or pure-new output, under a publisher thread swapping
/// continuously.
#[test]
fn prop_epoch_swap_never_mixes_snapshots() {
    let cfg = PipelineConfig::small();
    let mut land = workload::generate(&cfg);
    let old =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    // build the successor off to the side (a case-3 storm), like Alg 5;
    // pick a schema whose v1 column is live so the probes must map
    let schema = land
        .tree
        .schemas()
        .map(|s| s.id)
        .find(|&s| !old.column(s, metl::schema::VersionNo(1)).is_empty())
        .expect("a schema with a mapped v1 column");
    let fields = workload::evolved_fields(&land.tree, schema);
    let v = land.tree.add_version(schema, &fields);
    let (new, _report) = prepare_update(
        &old,
        &land.tree,
        &land.cdm,
        ChangeCase::AddedSchemaVersion { schema, v },
        StateI(1),
    );
    // a probe message per state, plus its expected pure output
    let probe = |dpm: &DpmSet, version| {
        let sv = land.tree.version(schema, version).unwrap();
        let mut rng = Rng::seed_from(9);
        let row =
            metl::source::random_row(&land.tree, schema, version, 1, &mut rng, 0.0);
        let msg = InMessage {
            key: 1,
            schema,
            version,
            state: dpm.state,
            ts_us: 0,
            fields: sv.attrs.iter().copied().zip(row.values).collect(),
        }
        .to_dense();
        let mapper = ParallelMapper::new(
            Arc::new(dpm.clone()),
            Arc::new(DcpmCache::new(dpm.state)),
        );
        let mut outs = mapper.map(&msg).unwrap();
        outs.sort_by_key(|o| (o.entity, o.version));
        (msg, outs)
    };
    let live_v = metl::schema::VersionNo(1);
    let (msg_old, outs_old) = probe(&old, live_v);
    let (msg_new, outs_new) = probe(&new, live_v);
    let epoch = EpochDmm::new(Arc::new(old.clone()));
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        let epoch_ref = &epoch;
        let stop_ref = &stop;
        let old_ref = &old;
        let new_ref = &new;
        scope.spawn(move || {
            let mut flip = false;
            while !stop_ref.load(std::sync::atomic::Ordering::Relaxed) {
                let next =
                    if flip { old_ref.clone() } else { new_ref.clone() };
                epoch_ref.publish(Arc::new(next));
                flip = !flip;
            }
        });
        for _ in 0..500 {
            let snap = epoch_ref.snapshot();
            // the snapshot is immutable: its state and blocks must always
            // belong to the same published set
            let (msg, expected) = if snap.state == StateI(0) {
                (&msg_old, &outs_old)
            } else {
                (&msg_new, &outs_new)
            };
            let mapper = ParallelMapper::with_threads(
                Arc::clone(&snap),
                Arc::new(DcpmCache::new(snap.state)),
                1,
            );
            let mut outs = mapper.map(msg).unwrap();
            outs.sort_by_key(|o| (o.entity, o.version));
            assert_eq!(&outs, expected, "mixed old/new snapshot observed");
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
}

/// Invariant: JSON codec roundtrips arbitrary values built from the sim's
/// value constructors.
#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::seed_from(0x1503);
    for _ in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = metl::util::json::parse(&text).unwrap();
        assert_eq!(back, v, "{text}");
        let pretty = v.to_pretty();
        assert_eq!(metl::util::json::parse(&pretty).unwrap(), v);
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.gen_range(2_000_001) as f64 - 1e6) / 8.0),
        3 => {
            let n = rng.gen_range(12) as usize;
            Json::Str(
                (0..n)
                    .map(|_| {
                        let c = rng.gen_range(96) as u8 + 32;
                        c as char
                    })
                    .collect(),
            )
        }
        4 => Json::Arr(
            (0..rng.gen_range(4)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.gen_range(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Invariant: the state-sync check fires for every skewed state, never
/// for matching states (mapper-level §3.4 contract).
#[test]
fn prop_state_sync_contract() {
    let mut meta = Rng::seed_from(77);
    let cfg = random_cfg(&mut meta);
    let land = workload::generate(&cfg);
    for state in 0..5u64 {
        let dpm = Arc::new(
            DpmSet::from_matrix(
                &land.matrix, &land.tree, &land.cdm, StateI(state),
            )
            .unwrap(),
        );
        let cache = Arc::new(DcpmCache::new(StateI(state)));
        let mapper = ParallelMapper::new(dpm, cache);
        let node = land.tree.schemas().next().unwrap();
        let v = *node.versions.last().unwrap();
        let sv = land.tree.version(node.id, v).unwrap();
        for msg_state in 0..5u64 {
            let msg = InMessage {
                key: 1,
                schema: node.id,
                version: v,
                state: StateI(msg_state),
                ts_us: 0,
                fields: vec![(sv.attrs[0], Json::Num(1.0))],
            };
            let result = mapper.map(&msg);
            if msg_state == state {
                assert!(result.is_ok());
            } else {
                assert!(matches!(
                    result,
                    Err(metl::mapper::MapError::StateMismatch { .. })
                ));
            }
        }
    }
}

/// Invariant: the bounded delivery shuffle preserves the event multiset,
/// keeps per-key relative order (Kafka's actual guarantee) and never
/// displaces any item by more than the bound — for any batch size, key
/// cardinality and bound.
#[test]
fn prop_shuffle_bounded_invariants() {
    let mut meta = Rng::seed_from(0x5BFF);
    for trial in 0..40 {
        let n = meta.gen_range(300) as usize;
        let keys = 1 + meta.gen_range(12);
        let bound = meta.gen_range(50) as usize;
        let items: Vec<(u64, usize)> =
            (0..n).map(|i| (meta.gen_range(keys), i)).collect();
        let mut rng = Rng::seed_from(meta.next_u64());
        let out = shuffle_bounded(&items, |it| it.0, bound, &mut rng);
        let mut a = items.clone();
        let mut b = out.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "trial {trial}: multiset changed");
        for (pos, it) in out.iter().enumerate() {
            assert!(
                pos.abs_diff(it.1) <= bound,
                "trial {trial}: item {it:?} displaced to {pos} (bound {bound})"
            );
        }
        for k in 0..keys {
            let seq: Vec<usize> =
                out.iter().filter(|it| it.0 == k).map(|it| it.1).collect();
            assert!(
                seq.windows(2).all(|w| w[0] < w[1]),
                "trial {trial}: key {k} reordered: {seq:?}"
            );
        }
    }
}

/// Invariant: duplicate delivery only ever inserts adjacent repeats —
/// collapsing consecutive repeats recovers the original batch exactly,
/// and the reported count matches the growth.
#[test]
fn prop_duplicate_delivery_is_adjacent_and_counted() {
    let mut meta = Rng::seed_from(0xD00D);
    for trial in 0..40 {
        let n = meta.gen_range(400) as usize;
        let p = meta.f64() * 0.5;
        let items: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from(meta.next_u64());
        let (out, dups) = duplicate_delivery(&items, p, &mut rng);
        assert_eq!(out.len(), n + dups, "trial {trial}: count mismatch");
        let mut collapsed = out.clone();
        collapsed.dedup();
        assert_eq!(
            collapsed, items,
            "trial {trial}: a duplicate landed away from its original"
        );
    }
}

/// Invariant: hostile traces are a pure function of `(cfg, scenario,
/// seed)`, conserve the configured DML count for every scenario, stay
/// inside the service universe, and only attach hot-key ranks to
/// non-insert ops.
#[test]
fn prop_hostile_trace_deterministic_and_conserves_dmls() {
    let mut meta = Rng::seed_from(0x7A11);
    for trial in 0..12 {
        let mut cfg = PipelineConfig::small();
        cfg.trace_events = 32 + meta.gen_range(200) as usize;
        let seed = meta.next_u64();
        for scenario in Scenario::ALL {
            let a = hostile_trace(&cfg, scenario, &mut Rng::seed_from(seed));
            let b = hostile_trace(&cfg, scenario, &mut Rng::seed_from(seed));
            assert_eq!(a, b, "trial {trial}: {scenario} not deterministic");
            let mut dmls = 0;
            for op in &a {
                if let HostileOp::Dml { service, kind, rank } = op {
                    dmls += 1;
                    assert!(
                        *service < cfg.n_services,
                        "trial {trial}: {scenario} service {service}"
                    );
                    if *kind == DmlKind::Insert {
                        assert!(
                            rank.is_none(),
                            "trial {trial}: {scenario} insert with rank"
                        );
                    }
                }
            }
            assert_eq!(
                dmls, cfg.trace_events,
                "trial {trial}: {scenario} DML count"
            );
            assert_eq!(
                a.last(),
                Some(&HostileOp::Drain),
                "trial {trial}: {scenario} missing final drain"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Segmented-broker linearizability under real concurrency
// ---------------------------------------------------------------------------

/// Broker invariant: with racing keyed producers (mixing single and batch
/// produces) and two **independent** consumer groups draining live, the
/// log conserves the produced multiset exactly, keys stay sticky to one
/// partition, per-producer order survives inside every partition, and
/// both groups observe the identical per-partition record sequence — the
/// segmented log, not the consumers, is the source of truth.
#[test]
fn prop_concurrent_producers_and_groups_agree_on_the_log() {
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 3_000; // ≫ SEGMENT_RECORDS: chains must grow
    const KEYS: u64 = 13;
    const BATCH: u64 = 16;
    let t: Topic<u64> = Broker::new(4).create_topic("conc", 4);
    let total = (PRODUCERS * PER_PRODUCER) as usize;
    let encode = |prod: u64, seq: u64| (prod << 32) | seq;
    let key_of = |prod: u64, seq: u64| (prod * 31 + seq) % KEYS;
    let groups: Vec<Mutex<Vec<(usize, u64, u64, u64)>>> =
        (0..2).map(|_| Mutex::new(Vec::new())).collect();
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let done = &done;
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|prod| {
                let t = t.clone();
                scope.spawn(move || {
                    let mut seq = 0;
                    while seq < PER_PRODUCER {
                        // alternate windows of batched and single produces
                        if seq % (2 * BATCH) < BATCH {
                            let n = BATCH.min(PER_PRODUCER - seq);
                            t.produce_batch((seq..seq + n).map(|s| {
                                (key_of(prod, s), encode(prod, s))
                            }));
                            seq += n;
                        } else {
                            t.produce(key_of(prod, seq), encode(prod, seq));
                            seq += 1;
                        }
                    }
                })
            })
            .collect();
        for sink in &groups {
            for member in 0..2 {
                let t = t.clone();
                scope.spawn(move || {
                    let mut c = Consumer::new(t, member, 2);
                    let mut got = Vec::new();
                    loop {
                        let batch = c.poll(111);
                        if batch.is_empty() {
                            if done.load(Ordering::Acquire) && c.lag() == 0 {
                                break;
                            }
                            std::thread::yield_now();
                            continue;
                        }
                        for (p, rec) in batch {
                            got.push((p, rec.offset, rec.key, rec.value));
                        }
                        c.commit();
                    }
                    sink.lock().unwrap().extend(got);
                });
            }
        }
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
    });
    let mut views = Vec::new();
    for sink in &groups {
        let mut got = sink.lock().unwrap().clone();
        assert_eq!(got.len(), total, "group lost or duplicated records");
        // multiset conservation: every (producer, seq) exactly once
        let mut values: Vec<u64> = got.iter().map(|&(.., v)| v).collect();
        values.sort_unstable();
        let mut expected: Vec<u64> = (0..PRODUCERS)
            .flat_map(|prod| {
                (0..PER_PRODUCER).map(move |s| encode(prod, s))
            })
            .collect();
        expected.sort_unstable();
        assert_eq!(values, expected, "multiset not conserved");
        // (partition, offset) is the log's authoritative order
        got.sort_unstable_by_key(|&(p, o, _, _)| (p, o));
        views.push(got);
    }
    assert_eq!(views[0], views[1], "consumer groups observed different logs");
    // offsets are contiguous per partition and account for every record
    for p in 0..t.n_partitions() {
        let offs: Vec<u64> = views[0]
            .iter()
            .filter(|&&(vp, ..)| vp == p)
            .map(|&(_, o, _, _)| o)
            .collect();
        assert_eq!(offs, (0..offs.len() as u64).collect::<Vec<_>>());
        assert_eq!(offs.len() as u64, t.end_offset(p));
    }
    // key stickiness + per-producer order inside each partition
    let mut key_home: HashMap<u64, usize> = HashMap::new();
    let mut last_seq: HashMap<(usize, u64), u64> = HashMap::new();
    for &(p, _, key, v) in &views[0] {
        assert_eq!(
            *key_home.entry(key).or_insert(p),
            p,
            "key {key} hopped partitions"
        );
        let (prod, seq) = (v >> 32, v & 0xFFFF_FFFF);
        if let Some(prev) = last_seq.insert((p, prod), seq) {
            assert!(
                seq > prev,
                "producer {prod} reordered in partition {p}: {prev} then {seq}"
            );
        }
    }
}

/// Broker invariant: the committed watermark is monotone and atomic under
/// a racing batch producer. A reader that observes end-offset E can
/// immediately read all E records below it — no holes, no torn batches —
/// and neither a partition watermark nor the topic total ever moves
/// backwards.
#[test]
fn prop_watermark_monotonic_and_gapless_under_racing_producer() {
    const ROUNDS: u64 = 2_000;
    let t: Topic<u64> = Broker::new(2).create_topic("mono", 2);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let stop = &stop;
        let tp = t.clone();
        let producer = scope.spawn(move || {
            let mut i = 0u64;
            for round in 0..ROUNDS {
                let n = round % 7 + 1; // varying batch sizes
                tp.produce_batch((i..i + n).map(|k| (k, k)));
                i += n;
            }
        });
        for _ in 0..2 {
            let tr = t.clone();
            scope.spawn(move || {
                let mut last = vec![0u64; tr.n_partitions()];
                let mut last_total = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let total = tr.total_records();
                    assert!(total >= last_total, "total_records went backwards");
                    last_total = total;
                    for p in 0..tr.n_partitions() {
                        let end = tr.end_offset(p);
                        assert!(end >= last[p], "watermark went backwards");
                        last[p] = end;
                        let recs = tr.fetch(p, 0, end as usize);
                        assert_eq!(
                            recs.len() as u64,
                            end,
                            "hole below the watermark"
                        );
                        if let Some(rec) = recs.last() {
                            assert_eq!(rec.offset, end - 1);
                        }
                    }
                }
            });
        }
        producer.join().unwrap();
        stop.store(true, Ordering::Release);
    });
    assert_eq!(t.total_records(), (0..ROUNDS).map(|r| r % 7 + 1).sum::<u64>());
}

/// Broker invariant: at-least-once delivery across crash/rewind while the
/// producer is still live. Commits move the group's durable offsets only
/// forward; a rewind redelivers everything past the last commit; and when
/// the dust settles every offset of every partition was delivered at
/// least once — duplicates allowed, gaps never.
#[test]
fn prop_rewind_redelivers_at_least_once_under_live_producer() {
    const EVENTS: u64 = 4_000;
    let t: Topic<u64> = Broker::new(3).create_topic("alo", 3);
    let done = AtomicBool::new(false);
    let seen: Mutex<Vec<(usize, u64, u64)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        let done = &done;
        let seen = &seen;
        let tp = t.clone();
        let producer = scope.spawn(move || {
            for i in 0..EVENTS {
                tp.produce(i % 11, i);
            }
        });
        let tc = t.clone();
        scope.spawn(move || {
            let mut c = Consumer::new(tc, 0, 1);
            let mut all = Vec::new();
            let mut last_committed = c.committed_offsets();
            let mut round = 0u64;
            loop {
                let batch = c.poll(97);
                if batch.is_empty() {
                    if done.load(Ordering::Acquire) && c.lag() == 0 {
                        break;
                    }
                    std::thread::yield_now();
                    continue;
                }
                for (p, rec) in &batch {
                    all.push((*p, rec.offset, rec.value));
                }
                round += 1;
                if round % 5 == 0 {
                    // simulated crash before the commit
                    c.rewind_to_committed();
                } else {
                    c.commit();
                    let now = c.committed_offsets();
                    for (&(pa, a), &(pb, b)) in
                        last_committed.iter().zip(&now)
                    {
                        assert_eq!(pa, pb);
                        assert!(b >= a, "committed offset moved backwards");
                    }
                    last_committed = now;
                }
            }
            seen.lock().unwrap().extend(all);
        });
        producer.join().unwrap();
        done.store(true, Ordering::Release);
    });
    let seen = seen.into_inner().unwrap();
    for p in 0..t.n_partitions() {
        let end = t.end_offset(p);
        let mut offs: Vec<u64> = seen
            .iter()
            .filter(|&&(sp, ..)| sp == p)
            .map(|&(_, o, _)| o)
            .collect();
        offs.sort_unstable();
        offs.dedup();
        assert_eq!(
            offs,
            (0..end).collect::<Vec<_>>(),
            "partition {p} skipped offsets across rewinds"
        );
    }
    // the contract is at-least-once, not exactly-once: rewinds redeliver
    assert!(seen.len() as u64 >= t.total_records());
}

/// Invariant: the Zipf sampler stays in `[0, n)` and the head rank is at
/// least as hot as the tail, for any universe size and exponent.
#[test]
fn prop_zipf_in_range_and_head_heavy() {
    let mut meta = Rng::seed_from(0x21FF);
    for trial in 0..20 {
        let n = 2 + meta.gen_range(60) as usize;
        let s = 0.8 + meta.f64() * 1.2;
        let zipf = Zipf::new(n, s);
        assert_eq!(zipf.n(), n);
        let mut rng = Rng::seed_from(meta.next_u64());
        let mut counts = vec![0u64; n];
        for _ in 0..3000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        assert_eq!(counts.iter().sum::<u64>(), 3000, "trial {trial}");
        assert!(
            counts[0] >= counts[n - 1],
            "trial {trial}: n={n} s={s}: {counts:?}"
        );
    }
}
