"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

Sweeps shapes/dtypes with hypothesis and asserts allclose against ref.py.
All pallas calls run interpret=True (CPU image; see DESIGN.md).
"""

import numpy as np
import pytest
import jax.numpy as jnp

try:  # hypothesis is absent from the offline image; the seeded sweeps
    # below keep the randomized coverage either way.
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(**_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis unavailable")(fn)

        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        def __getattr__(self, _name):
            def _strategy(*_args, **_kw):
                return None

            return _strategy

    st = _AnyStrategy()

from compile.kernels import block_map as bm
from compile.kernels import permute_extract as pe
from compile.kernels import ref


def rand_subpermutation(rng, q, p, rank=None):
    """Random QxP 0/1 matrix with <=1 one per row and per column."""
    m = np.zeros((q, p), dtype=np.float32)
    k = rank if rank is not None else rng.integers(0, min(q, p) + 1)
    rows = rng.permutation(q)[:k]
    cols = rng.permutation(p)[:k]
    m[rows, cols] = 1.0
    return m


def rand_presence(rng, b, p, density=0.5):
    return (rng.random((b, p)) < density).astype(np.float32)


TILE_CASES = [
    # (B, P, Q, bb, bq, bp)
    (8, 16, 16, 8, 8, 8),
    (16, 32, 16, 8, 8, 16),
    (128, 128, 128, 128, 128, 128),
    (256, 128, 256, 64, 64, 32),
]


@pytest.mark.parametrize("b,p,q,bb,bq,bp", TILE_CASES)
def test_block_map_matches_ref(b, p, q, bb, bq, bp):
    rng = np.random.default_rng(b * 1000 + p + q)
    m = rand_subpermutation(rng, q, p)
    x = rand_presence(rng, b, p)
    presence, src_idx = bm.block_map(jnp.asarray(m), jnp.asarray(x),
                                     bb=bb, bq=bq, bp=bp)
    ref_presence, ref_idx = ref.block_map_ref(jnp.asarray(m), jnp.asarray(x))
    np.testing.assert_allclose(presence, ref_presence, atol=1e-6)
    np.testing.assert_allclose(src_idx, ref_idx, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    b_tiles=st.integers(1, 3),
    p_tiles=st.integers(1, 3),
    q_tiles=st.integers(1, 3),
    tile=st.sampled_from([8, 16]),
    density=st.floats(0.0, 1.0),
)
def test_block_map_hypothesis_sweep(seed, b_tiles, p_tiles, q_tiles, tile,
                                    density):
    rng = np.random.default_rng(seed)
    b, p, q = b_tiles * tile, p_tiles * tile, q_tiles * tile
    m = rand_subpermutation(rng, q, p)
    x = rand_presence(rng, b, p, density)
    presence, src_idx = bm.block_map(jnp.asarray(m), jnp.asarray(x),
                                     bb=tile, bq=tile, bp=tile)
    ref_presence, ref_idx = ref.block_map_ref(jnp.asarray(m), jnp.asarray(x))
    np.testing.assert_allclose(presence, ref_presence, atol=1e-6)
    np.testing.assert_allclose(src_idx, ref_idx, atol=1e-6)


def test_block_map_semantics_gather():
    """presence/src_idx must agree with direct gather semantics: if
    m[q,p]==1 and x[b,p]==1 then slot q of message b is fed from p."""
    rng = np.random.default_rng(7)
    q_n, p_n, b_n = 16, 24, 8
    m = rand_subpermutation(rng, q_n, p_n, rank=10)
    x = rand_presence(rng, b_n, p_n, 0.6)
    presence, src_idx = bm.block_map(jnp.asarray(m), jnp.asarray(x),
                                     bb=8, bq=8, bp=8)
    presence = np.asarray(presence)
    src_idx = np.asarray(src_idx)
    for bi in range(b_n):
        for qi in range(q_n):
            ps = np.nonzero(m[qi])[0]
            if len(ps) == 1 and x[bi, ps[0]] == 1.0:
                assert presence[bi, qi] == 1.0
                assert src_idx[bi, qi] == ps[0]
            else:
                assert presence[bi, qi] == 0.0
                assert src_idx[bi, qi] == -1.0


def test_block_map_empty_and_full():
    b, p, q = 16, 16, 16
    zeros_m = jnp.zeros((q, p), jnp.float32)
    eye_m = jnp.eye(q, p, dtype=jnp.float32)
    x = jnp.ones((b, p), jnp.float32)
    pres0, idx0 = bm.block_map(zeros_m, x, bb=8, bq=8, bp=8)
    assert float(jnp.sum(pres0)) == 0.0
    assert bool(jnp.all(idx0 == -1.0))
    pres1, idx1 = bm.block_map(eye_m, x, bb=8, bq=8, bp=8)
    assert bool(jnp.all(pres1 == 1.0))
    np.testing.assert_allclose(
        np.asarray(idx1), np.tile(np.arange(q, dtype=np.float32), (b, 1)))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    q_tiles=st.integers(1, 4),
    p_tiles=st.integers(1, 4),
    density=st.floats(0.0, 1.0),
)
def test_permute_extract_hypothesis(seed, q_tiles, p_tiles, density):
    rng = np.random.default_rng(seed)
    tile = 8
    q, p = q_tiles * tile, p_tiles * tile
    mb = (rng.random((q, p)) < density).astype(np.float32)
    row_deg, col_deg, ones = pe.permute_extract(jnp.asarray(mb),
                                                bq=tile, bp=tile)
    r_ref, c_ref, o_ref = ref.permute_extract_ref(jnp.asarray(mb))
    np.testing.assert_allclose(row_deg, r_ref, atol=1e-6)
    np.testing.assert_allclose(col_deg, c_ref, atol=1e-6)
    np.testing.assert_allclose(ones, o_ref, atol=1e-6)


@pytest.mark.parametrize("seed", range(10))
def test_block_map_seeded_sweep(seed):
    """Deterministic stand-in for the hypothesis sweep (always runs)."""
    rng = np.random.default_rng(seed)
    tile = int(rng.choice([8, 16]))
    b = tile * int(rng.integers(1, 4))
    p = tile * int(rng.integers(1, 4))
    q = tile * int(rng.integers(1, 4))
    m = rand_subpermutation(rng, q, p)
    x = rand_presence(rng, b, p, float(rng.random()))
    presence, src_idx = bm.block_map(jnp.asarray(m), jnp.asarray(x),
                                     bb=tile, bq=tile, bp=tile)
    ref_presence, ref_idx = ref.block_map_ref(jnp.asarray(m), jnp.asarray(x))
    np.testing.assert_allclose(presence, ref_presence, atol=1e-6)
    np.testing.assert_allclose(src_idx, ref_idx, atol=1e-6)


@pytest.mark.parametrize("seed", range(8))
def test_permute_extract_seeded_sweep(seed):
    """Deterministic stand-in for the hypothesis sweep (always runs)."""
    rng = np.random.default_rng(1000 + seed)
    tile = 8
    q = tile * int(rng.integers(1, 5))
    p = tile * int(rng.integers(1, 5))
    mb = (rng.random((q, p)) < float(rng.random())).astype(np.float32)
    row_deg, col_deg, ones = pe.permute_extract(jnp.asarray(mb),
                                                bq=tile, bp=tile)
    r_ref, c_ref, o_ref = ref.permute_extract_ref(jnp.asarray(mb))
    np.testing.assert_allclose(row_deg, r_ref, atol=1e-6)
    np.testing.assert_allclose(col_deg, c_ref, atol=1e-6)
    np.testing.assert_allclose(ones, o_ref, atol=1e-6)


def test_permute_extract_detects_valid_permutation():
    rng = np.random.default_rng(3)
    m = rand_subpermutation(rng, 16, 16, rank=9)
    row_deg, col_deg, ones = pe.permute_extract(jnp.asarray(m), bq=8, bp=8)
    assert float(jnp.max(row_deg)) <= 1.0
    assert float(jnp.max(col_deg)) <= 1.0
    assert float(ones) == 9.0
