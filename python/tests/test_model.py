"""L2 model shape/semantics tests + AOT lowering smoke."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_bulk_map_shapes():
    m = jnp.zeros((128, 128), jnp.float32)
    x = jnp.zeros((256, 128), jnp.float32)
    presence, src_idx = model.bulk_map(m, x)
    assert presence.shape == (256, 128)
    assert src_idx.shape == (256, 128)


def test_bulk_map_matches_ref_on_aot_shape():
    rng = np.random.default_rng(42)
    m = np.zeros((128, 128), np.float32)
    k = 37
    rows = rng.permutation(128)[:k]
    cols = rng.permutation(128)[:k]
    m[rows, cols] = 1.0
    x = (rng.random((256, 128)) < 0.4).astype(np.float32)
    presence, src_idx = model.bulk_map(jnp.asarray(m), jnp.asarray(x))
    rp, ri = ref.block_map_ref(jnp.asarray(m), jnp.asarray(x))
    np.testing.assert_allclose(presence, rp, atol=1e-6)
    np.testing.assert_allclose(src_idx, ri, atol=1e-6)


def test_bulk_map_multi_vmaps_column_superset():
    rng = np.random.default_rng(5)
    ms = np.zeros((3, 128, 128), np.float32)
    for kblk in range(3):
        rows = rng.permutation(128)[:10]
        cols = rng.permutation(128)[:10]
        ms[kblk, rows, cols] = 1.0
    x = (rng.random((128, 128)) < 0.5).astype(np.float32)
    presence, src_idx = model.bulk_map_multi(jnp.asarray(ms), jnp.asarray(x))
    assert presence.shape == (3, 128, 128)
    for kblk in range(3):
        rp, ri = ref.block_map_ref(jnp.asarray(ms[kblk]), jnp.asarray(x))
        np.testing.assert_allclose(presence[kblk], rp, atol=1e-6)
        np.testing.assert_allclose(src_idx[kblk], ri, atol=1e-6)


def test_degrees_fn():
    rng = np.random.default_rng(11)
    mb = (rng.random((128, 128)) < 0.1).astype(np.float32)
    fn, specs = model.make_degrees_fn(128, 128)
    row_deg, col_deg, ones = fn(jnp.asarray(mb))
    np.testing.assert_allclose(row_deg, mb.sum(axis=1), atol=1e-6)
    np.testing.assert_allclose(col_deg, mb.sum(axis=0), atol=1e-6)
    assert float(ones[0]) == float(mb.sum())


@pytest.mark.parametrize("batch,p,q", [(256, 128, 128)])
def test_aot_lowering_produces_hlo_text(batch, p, q):
    from compile import aot

    text = aot.lower_bulk_map(batch, p, q)
    assert "HloModule" in text
    # two outputs in a tuple
    assert "tuple" in text.lower()


def test_aot_degrees_lowering():
    from compile import aot

    text = aot.lower_degrees(128, 128)
    assert "HloModule" in text


def test_jit_executes_lowered_semantics():
    """jit-compiled variant equals eager pallas-interpret result."""
    rng = np.random.default_rng(1)
    fn, specs = model.make_bulk_map_fn(128, 128, 128)
    m = np.eye(128, dtype=np.float32)
    x = (rng.random((128, 128)) < 0.3).astype(np.float32)
    jp, ji = jax.jit(fn)(jnp.asarray(m), jnp.asarray(x))
    ep, ei = fn(jnp.asarray(m), jnp.asarray(x))
    np.testing.assert_allclose(jp, ep, atol=1e-6)
    np.testing.assert_allclose(ji, ei, atol=1e-6)
