"""Pure-jnp oracles for the METL bulk-mapping kernels.

These are the ground truth the Pallas kernels (block_map.py,
permute_extract.py) are validated against in python/tests/.

Semantics (paper §4.2/§5.5): a mapping block ``M`` (shape Q×P, values in
{0,1}, at most one 1 per row and per column — a sub-permutation matrix)
applies the paper's mapping function ``ncd_q <- m_qp * nad_p`` to a *batch*
of incoming messages. A message is encoded as a presence vector
``x in {0,1}^P`` (``nad_p``: 1 iff attribute p carries a non-"null" data
object). The bulk path additionally needs, for every produced output slot q,
the *source index* p whose data object must be relabelled onto the CDM
attribute c_q — that is what lets the rust coordinator move the actual
payload bytes without python on the request path.
"""

import jax.numpy as jnp


def block_map_ref(m, x):
    """Reference bulk mapping.

    Args:
      m: (Q, P) float array, entries in {0, 1}; sub-permutation matrix.
      x: (B, P) float array, entries in {0, 1}; batch of presence vectors.

    Returns:
      presence: (B, Q) float, presence[b, q] = sum_p m[q, p] * x[b, p]
        (the paper's mapping function, vectorized over the batch).
      src_idx:  (B, Q) float, the 0-based source attribute index p feeding
        output slot q for message b, or -1.0 when the slot stays "null".
    """
    presence = x @ m.T
    # Encode indices as p+1 so that index 0 is distinguishable from "absent",
    # then shift back and mark absent slots with -1.
    idx1 = (x * (jnp.arange(x.shape[1], dtype=x.dtype) + 1.0)) @ m.T
    src_idx = jnp.where(presence > 0.5, idx1 - 1.0, -1.0)
    return presence, src_idx


def permute_extract_ref(mb):
    """Reference row/column occupancy used to extract the largest
    permutation matrix from a rectangular mapping block (paper §5.3.1).

    Args:
      mb: (Q, P) float array with entries in {0, 1} (general block, not
        necessarily a permutation).

    Returns:
      row_deg: (Q,) float — number of 1s per row.
      col_deg: (P,) float — number of 1s per column.
      ones:    () float — total number of 1s in the block.
    """
    row_deg = jnp.sum(mb, axis=1)
    col_deg = jnp.sum(mb, axis=0)
    ones = jnp.sum(mb)
    return row_deg, col_deg, ones
