"""L1 Pallas kernel: row/column occupancy of a mapping block.

Feeds the largest-permutation-matrix extraction of Alg 2 / Alg 3 (paper
§5.3): a rectangular mapping block is sized down to its largest permutation
sub-matrix by discarding all-zero rows and columns; the degrees computed
here are exactly the evidence needed (a block is a valid 1:1 mapping iff
every row/col degree is ≤ 1; the permutation rank is the number of 1s).

The grid walks (Q/bq, P/bp) tiles; row/col degree outputs are revisited
along the reduction axis and accumulate in VMEM, same schedule family as
block_map.py. interpret=True on this image.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE = 128


def _degrees_kernel(mb_ref, row_ref, col_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_row():
        row_ref[...] = jnp.zeros_like(row_ref)

    @pl.when(i == 0)
    def _init_col():
        col_ref[...] = jnp.zeros_like(col_ref)

    tile = mb_ref[...]
    row_ref[...] += jnp.sum(tile, axis=1, keepdims=True)
    col_ref[...] += jnp.sum(tile, axis=0, keepdims=True)


def permute_extract(mb, *, bq=DEFAULT_TILE, bp=DEFAULT_TILE, interpret=True):
    """Row/col degrees of a (Q, P) 0/1 block via a tiled Pallas reduction.

    Returns (row_deg (Q,), col_deg (P,), ones ()) matching
    ref.permute_extract_ref. Q and P must be multiples of the tile sizes;
    callers pad with zeros (padding adds zero degree, so results are exact).
    """
    q, p = mb.shape
    assert q % bq == 0 and p % bp == 0, mb.shape
    grid = (q // bq, p // bp)
    row2d, col2d = pl.pallas_call(
        _degrees_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bq, bp), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bp), lambda i, j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, p), jnp.float32),
        ],
        interpret=interpret,
    )(mb)
    row_deg = row2d[:, 0]
    col_deg = col2d[0, :]
    ones = jnp.sum(row_deg)
    return row_deg, col_deg, ones
