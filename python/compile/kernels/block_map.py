"""L1 Pallas kernel: tiled bulk application of a mapping block to a batch
of message presence vectors.

This is the TPU re-expression of the paper's parallel mapping (Alg 6): the
paper parallelizes over single mapping elements on JVM threads; on an
MXU-shaped accelerator the same independent-element structure is a dense
0/1 matmul ``Y[b, q] = sum_p M[q, p] * X[b, p]`` where the *batch* dimension
carries the paper's message-level parallelism. See DESIGN.md
§Hardware-Adaptation.

Tiling: the grid is (B/bb, Q/bq, P/bp) with the reduction axis innermost;
the output tile is revisited across the P sweep, so it stays resident in
VMEM and serves as the accumulator (the canonical Pallas matmul schedule).
Tile sizes default to 128 — the MXU systolic-array edge — so on a real TPU
each step is one MXU pass; under ``interpret=True`` (mandatory on this
CPU-PJRT image) the same schedule runs as numpy and is used for correctness
only.

VMEM budget per grid step (f32, defaults bb=bq=bp=128):
  X tile   128*128*4 = 64 KiB
  Mt tile  128*128*4 = 64 KiB
  out/acc  128*128*4 = 64 KiB
≈192 KiB resident (384 KiB with double-buffered input streams) — >40x
headroom inside the ~16 MiB/core VMEM of current TPUs. MXU utilization for
the AOT'd default shape (stacked batch 512×128×128) is a full-occupancy
schedule: every dot is 128³ with no masked lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE = 128


def _block_map_kernel(x_ref, mt_ref, o_ref, *, n_p_tiles):
    """One (b-tile, q-tile, p-slab) grid step: o += X_tile @ Mt_tile."""
    p_step = pl.program_id(2)

    @pl.when(p_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], mt_ref[...], preferred_element_type=jnp.float32
    )


def block_map_matmul(x, m_t, *, bb=DEFAULT_TILE, bq=DEFAULT_TILE,
                     bp=DEFAULT_TILE, interpret=True):
    """Tiled ``x @ m_t`` via Pallas. x: (B, P), m_t: (P, Q) -> (B, Q).

    Shapes must be multiples of the tile sizes; the L2 model pads.
    """
    b, p = x.shape
    p2, q = m_t.shape
    assert p == p2, (x.shape, m_t.shape)
    assert b % bb == 0 and q % bq == 0 and p % bp == 0, (x.shape, m_t.shape)
    n_p_tiles = p // bp
    grid = (b // bb, q // bq, n_p_tiles)
    return pl.pallas_call(
        functools.partial(_block_map_kernel, n_p_tiles=n_p_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bp), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bq), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bq), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, q), jnp.float32),
        interpret=interpret,
    )(x, m_t)


def block_map(m, x, *, bb=DEFAULT_TILE, bq=DEFAULT_TILE, bp=DEFAULT_TILE,
              interpret=True):
    """Full bulk mapping: returns (presence, src_idx) like ref.block_map_ref.

    Two planes share one M tile stream: the presence plane carries x, the
    index plane carries ``x * (arange(P)+1)``; both are mapped by the same
    0/1 block, so we stack them on the batch axis and do a single tiled
    matmul — one M fetch serves both planes.
    """
    bsz, p = x.shape
    idx_plane = x * (jnp.arange(p, dtype=x.dtype) + 1.0)
    stacked = jnp.concatenate([x, idx_plane], axis=0)  # (2B, P)
    out = block_map_matmul(stacked, m.T, bb=bb, bq=bq, bp=bp,
                           interpret=interpret)
    presence = out[:bsz]
    idx1 = out[bsz:]
    src_idx = jnp.where(presence > 0.5, idx1 - 1.0, -1.0)
    return presence, src_idx
