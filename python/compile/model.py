"""L2: the METL bulk-mapping compute graph (build-time JAX).

The rust coordinator's *bulk lane* (initial loads / snapshot replays,
paper §5.5 "horizontal scaling ... for initial loads") maps thousands of
messages against one mapping block at once. This module is the jax graph
that gets AOT-lowered to HLO text by aot.py and executed from rust via
PJRT; it calls the L1 Pallas kernels and is the only compute that crosses
the language boundary.

Graph: bulk_map(m, x) -> (presence (B,Q), src_idx (B,Q))
  m: (Q, P) 0/1 mapping block (a padded largest-permutation matrix, the
     dense ᵢDPM_rw rematerialized for the matmul lane)
  x: (B, P) batch of presence vectors (nad_p per message)

src_idx[b, q] == p means: relabel message b's data object at extracting
attribute p onto CDM attribute q (the paper's mapping function with the
relabelled-container semantics of §3.1). -1 means the slot stays "null" and
— per the dense-message rule of §5.5 — is omitted from the outgoing message.
"""

import jax.numpy as jnp

from compile.kernels import block_map as bm
from compile.kernels import permute_extract as pe


def bulk_map(m, x):
    """Batched mapping of presence vectors through one mapping block."""
    presence, src_idx = bm.block_map(m.astype(jnp.float32),
                                     x.astype(jnp.float32))
    return presence, src_idx


def bulk_map_multi(ms, x):
    """Map one incoming batch through a *column* of mapping blocks
    (paper: one incoming message maps to ᵢm' outgoing messages — the
    column super-set ᵢDCPM). ms: (K, Q, P); returns (K, B, Q) x2."""

    def one(m):
        return bulk_map(m, x)

    import jax

    presence, src_idx = jax.vmap(one)(ms)
    return presence, src_idx


def block_degrees(mb):
    """Row/col occupancy of a block — evidence for PM extraction (Alg 2/3)."""
    return pe.permute_extract(mb.astype(jnp.float32))


def make_bulk_map_fn(batch, p_attrs, q_attrs, impl="pallas"):
    """Shape-specialized entry point for AOT lowering (one executable per
    (B, P, Q, impl) variant; rust picks the variant from
    artifacts/manifest.json and pads to it).

    impl="pallas": the L1 tiled kernel — the TPU deployment schedule
    (grid while-loop in HLO, MXU-edge tiles).
    impl="jnp": the pure-jnp reference — lowers to one fused dot, which is
    the right layout for the CPU-PJRT backend this image runs (see
    EXPERIMENTS.md §Perf L2). Both are verified equal in python/tests.
    """

    from compile.kernels import ref

    def fn(m, x):
        if impl == "pallas":
            presence, src_idx = bulk_map(m, x)
        else:
            presence, src_idx = ref.block_map_ref(m, x)
        return (presence, src_idx)

    import jax

    m_spec = jax.ShapeDtypeStruct((q_attrs, p_attrs), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((batch, p_attrs), jnp.float32)
    return fn, (m_spec, x_spec)


def make_degrees_fn(q_attrs, p_attrs):
    """Shape-specialized degree reduction for AOT lowering."""

    def fn(mb):
        row_deg, col_deg, ones = block_degrees(mb)
        return (row_deg, col_deg, jnp.reshape(ones, (1,)))

    import jax

    spec = jax.ShapeDtypeStruct((q_attrs, p_attrs), jnp.float32)
    return fn, (spec,)
