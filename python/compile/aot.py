"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published `xla` 0.1.6
crate) rejects; the text parser reassigns ids and round-trips cleanly.
(See /opt/xla-example/README.md.)

Artifacts:
  artifacts/bulk_map_b{B}_p{P}_q{Q}.hlo.txt   one per shape variant
  artifacts/degrees_q{Q}_p{P}.hlo.txt
  artifacts/manifest.json                     variant index for rust

`make artifacts` runs this once; rust never shells out to python.
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


# (batch, p_attrs, q_attrs) variants the rust bulk lane can pick from.
# 128 is the MXU/tile edge the pallas kernel is scheduled for; the small
# variant keeps smoke tests fast.
BULK_VARIANTS = [
    (256, 128, 128),
    (1024, 128, 128),
]
DEGREE_VARIANTS = [
    (128, 128),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bulk_map(batch, p, q, impl="pallas"):
    fn, specs = model.make_bulk_map_fn(batch, p, q, impl=impl)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_degrees(q, p):
    fn, specs = model.make_degrees_fn(q, p)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "bulk_map": [], "degrees": []}

    for batch, p, q in BULK_VARIANTS:
        # two impls per shape: the pallas TPU schedule and the jnp fused-dot
        # CPU layout (runtime picks per platform; METL_BULK_IMPL overrides)
        for impl in ("pallas", "jnp"):
            name = f"bulk_map_{impl}_b{batch}_p{p}_q{q}.hlo.txt"
            text = lower_bulk_map(batch, p, q, impl=impl)
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(text)
            manifest["bulk_map"].append(
                {"file": name, "batch": batch, "p": p, "q": q, "impl": impl,
                 "outputs": ["presence[b,q]", "src_idx[b,q]"]}
            )
            print(f"wrote {name} ({len(text)} chars)", file=sys.stderr)

    for q, p in DEGREE_VARIANTS:
        name = f"degrees_q{q}_p{p}.hlo.txt"
        text = lower_degrees(q, p)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["degrees"].append({"file": name, "q": q, "p": p})
        print(f"wrote {name} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({out_dir})", file=sys.stderr)


if __name__ == "__main__":
    main()
